"""End-to-end driver example: train a ~100M-param llama-style model for a
few hundred steps with the production substrate (data pipeline, grad
accumulation, checkpointing, straggler monitor), selectable paper
optimizers included.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(CPU: takes a while; use --d-model 256 --layers 4 for a fast demo)
"""
import argparse

import jax

from repro import configs
from repro.data import pipeline as dp
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.models.sharding import use_mesh
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod
from repro.train.straggler import StepMonitor
from repro.train.train_step import build_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--d-model", type=int, default=768)
ap.add_argument("--layers", type=int, default=12)
ap.add_argument("--optimizer", default="adamw")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

# ~100M params: 12L × 768d, llama3-style
cfg = configs.get("llama3.2-3b").scaled(
    num_layers=args.layers, d_model=args.d_model, num_heads=12,
    num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
    dtype="float32", remat="none")
mesh = make_host_mesh()

with mesh, use_mesh(mesh):
    model = build(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        model.specs()[0]))
    print(f"model: {n_params/1e6:.1f}M params")
    ocfg = opt_mod.OptimizerConfig(name=args.optimizer, lr=3e-4,
                                   warmup_steps=20, total_steps=args.steps)
    opt_init, opt_update = opt_mod.make_optimizer(ocfg)
    step = jax.jit(build_train_step(model, opt_update, microbatches=2),
                   donate_argnums=(0, 1))
    dc = dp.from_model(cfg, global_batch=8, seq_len=128)
    batch_fn = jax.jit(lambda s: dp.in_graph_batch(dc, s))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt_init(params)
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
    mon = StepMonitor()
    for s in range(args.steps):
        mon.start()
        params, opt_state, m = step(params, opt_state, batch_fn(s))
        v = mon.stop()
        if s % 20 == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss={float(m['loss']):.4f} "
                  f"dt={v['dt']*1e3:.0f}ms")
        if (s + 1) % 100 == 0:
            saver.save_async(s + 1, (params, opt_state),
                             extra={"data_step": s + 1})
    saver.wait()
    print("done; checkpoints in", args.ckpt_dir)
