"""Quickstart: the paper's core API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Distributed matrices, SVD via the driver/cluster split, and a LASSO solve
with the TFOCS port — all on whatever devices are available.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distmat import RowMatrix, CoordinateMatrix, SparseRowMatrix
from repro.core.linalg import compute_svd, tsqr
from repro.core.tfocs import solve_lasso, TfocsOptions

rng = np.random.default_rng(0)

# --- RowMatrix: tall-skinny data, distributed by rows --------------------
A = rng.normal(size=(10_000, 64)).astype(np.float32)
rm = RowMatrix.create(A)                     # row-sharded across the mesh
print("column means:", np.asarray(rm.column_stats()["mean"])[:4], "...")

# --- SVD: matrix ops on the cluster, vector ops on the driver ------------
res = compute_svd(rm, k=5)                   # gram path (n is small)
print("top-5 singular values:", np.asarray(res.s))
print("vs numpy:            ", np.linalg.svd(A, compute_uv=False)[:5])

# --- Square & sparse: the ARPACK-analogue matrix-free Lanczos path -------
m = n = 2000
nnz = 40_000
ri, ci = rng.integers(0, m, nnz), rng.integers(0, n, nnz)
va = rng.normal(size=nnz).astype(np.float32)
cm = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                             jnp.asarray(va), (m, n))
res2 = compute_svd(cm, k=3, mode="lanczos", tol=1e-5)
print("sparse top-3 σ:", np.asarray(res2.s),
      f"(Lanczos restarts: {int(res2.info['restarts'])})")

# --- Sparse distributed matrices: block-sparse rows on the MXU -----------
# SparseRowMatrix shards block-rows across devices; each shard is a BlockELL
# whose multiplies run the Pallas BSR kernels, with a density-aware fallback
# to dense GEMM when the shard is too dense for block-sparse to pay off.
bs = 64
mask = rng.random((4096 // bs, 512 // bs)) < 0.05          # 5% block density
S = (np.kron(mask, np.ones((bs, bs)))
     * rng.normal(size=(4096, 512))).astype(np.float32)
srm = SparseRowMatrix.from_dense(S, bs=bs)                 # or bs="auto"
print(f"SparseRowMatrix: bs={srm.bs} ell={srm.ell} "
      f"block_density={srm.block_density():.3f}")

# The whole SVD loop (matrix on the cluster, vectors on the driver) runs
# against block-sparse storage — Lanczos only ever calls matvec/rmatvec.
res3 = compute_svd(srm, k=3, tol=1e-6)
print("sparse-row top-3 σ:", np.asarray(res3.s))
print("vs numpy:          ", np.linalg.svd(S, compute_uv=False)[:3])

# Sampled DIMSUM column similarities: threshold=0 is exact; larger
# thresholds sample entries with the paper's oversampling probability
# min(1, γ/‖cᵢ‖‖cⱼ‖), trading accuracy below the threshold for flops.
sim = srm.column_similarities(threshold=0.25)
print("DIMSUM(0.25) sample:", np.asarray(sim)[0, :4])

# Conversions are shuffle-free: COO → block-sparse bins entries into
# blocks in one vectorized pass, densify stays on-shard.
cm2 = cm.to_sparse_row_matrix(bs="auto")
print("COO → SparseRowMatrix:", cm2.shape, f"bs={cm2.bs}")

# --- TSQR -----------------------------------------------------------------
Q, R = tsqr(rm)
print("TSQR ‖QᵀQ − I‖:",
      float(jnp.linalg.norm(jnp.asarray(Q.to_local()).T
                            @ jnp.asarray(Q.to_local()) - jnp.eye(64))))

# --- LASSO via the TFOCS port ---------------------------------------------
xt = np.zeros(64, np.float32)
xt[:6] = rng.normal(size=6) * 3
b = (A @ xt + 0.1 * rng.normal(size=10_000)).astype(np.float32)
x, info = solve_lasso(rm, jnp.asarray(b), lam=2.0,
                      opts=TfocsOptions(max_iters=200, restart=True))
print(f"LASSO: {int(info['iterations'])} iters, "
      f"{int(info['n_restarts'])} restarts; "
      f"recovered support: {np.nonzero(np.abs(np.asarray(x)) > 0.1)[0]}")

# --- Fused single-pass gradients ------------------------------------------
# Row-separable losses (least squares, logistic) let the optimizer hot loop
# compute f(Ax), the gradient Aᵀ∇f(Ax), AND the image Ax in ONE streaming
# pass over the distributed matrix (kernels/fusedgrad) instead of the two
# passes of apply + adjoint.  Proximal gradient (`gra`) and L-BFGS take the
# fused path automatically whenever the roofline dispatch prices it ahead
# (on HBM-bound shards that is ~2× less matrix traffic per iteration).
# Accelerated variants over a QUADRATIC loss get their own one-pass engine
# (plan="fused_affine"): the gradient is affine in cached u = Aᵀ(w∘A·)
# vectors, so acc/acc_b/acc_rb also pay a single A-pass per backtracking
# attempt; non-quadratic acc* keep the cached two-pass scheme.  Opt out
# with fused=False (solve_* / minimize / TfocsOptions all accept it).
from repro.core.tfocs import SmoothQuad, LinopMatrix, ProxZero, tfocs

linop = LinopMatrix(rm)
quad = SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                  weights=linop.row_weights())
xg, info_g = tfocs(quad, linop, ProxZero(), jnp.zeros(64),
                   TfocsOptions(max_iters=100, accel=False,
                                backtracking=True))     # fused="auto"
print(f"fused gra: {int(info_g['iterations'])} iters "
      f"(fused path: {bool(info_g['fused'])}, "
      f"one A-pass per backtracking attempt)")

# --- Low-precision compute: bytes are the bottleneck ----------------------
# The A-stream dominates every kernel above, so moving fewer bytes is the
# one optimization that compounds: RowMatrix can STORE its shards in bf16
# (or fp8) while every kernel upcasts tiles on-chip and accumulates in f32;
# SparseRowMatrix can quantize BlockELL data to int8 with per-block scales;
# and the fused-gradient psum can ship int8 payloads with error feedback
# ("psum8"), so nothing is lost across iterations.  Measured on the
# benchmark shapes (PYTHONPATH=src python -m benchmarks.run --only
# precision):
#
#   format      bytes moved      modeled speedup   solution error vs f32
#   bf16 store  2x fewer         1.86x (V5E)       ~5e-4   (at tol 1e-5)
#   psum8 wire  ~4x fewer/pass   comm-bound wins   ~1e-7   (EF-corrected)
#   int8 BSR    4.0x fewer       bandwidth-bound   ~7e-3   (operator quant)
#
# The solver front door prices this per-solve: precision="auto" (the
# default) asks the planner, which only admits a format when its guard is
# below the requested tolerance (bf16 needs tol ≥ 1e-5, int8 ≥ 1e-3,
# psum8 ≥ 1e-6) AND the modeled byte savings clear a floor.  Every solve
# reports what actually ran:
from repro import api

L0 = float(np.linalg.norm(A, 2) ** 2)
r32 = api.solve(api.SolveRequest(A=rm, b=b, loss="quad", method="gra",
                                 tol=1e-9, max_iters=300, L0=L0))
rlo = api.solve(api.SolveRequest(A=rm, b=b, loss="quad", method="gra",
                                 tol=1e-4, max_iters=300, L0=L0,
                                 precision="bf16"))   # or "auto"/"psum8"
drift = float(jnp.linalg.norm(rlo.x - r32.x)
              / jnp.linalg.norm(r32.x))
print(f"\nprecision: tol=1e-9 ran {r32.info['precision']}, "
      f"forced bf16 ran {rlo.info['precision']} "
      f"(drift vs f32: {drift:.1e})")

# store_dtype=f32 is BIT-identical to the unquantized path, so flipping
# precision off is always safe; rm.astype_store(jnp.bfloat16) converts a
# live matrix.  The planner exposes the same decision offline — pass the
# solve tolerance in the context and explain() prints the admitted
# formats, the modeled bytes of each, and what the pick saved:
#
#     p = planner.plan("grad", {"m": 8192, "n": 2048}, machine=machine.V5E,
#                      context={"tol": 1e-4, "axes": (8,)})
#     p.precision        -> "bf16"
#     p.explain()        -> "... precision: bf16 (saved 33554432 modeled
#                            bytes vs f32)"

# --- Planning & calibration -----------------------------------------------
# Every dispatch decision above — kernel block configs, BSR-vs-dense,
# fused-vs-unfused, the SVD mode — went through ONE code path: the
# execution planner (launch/planner.py), pricing alternatives against one
# MachineModel (launch/machine.py).  plan() answers "what would run, and
# why" for any shape without running anything:
from repro.launch import planner

p = planner.plan("sparse_matmul",
                 {"m": 4096, "n": 2048, "nx": 1, "ell": 2, "bs": 128})
print(f"\nsparse shard -> {p.choice}  (modeled {p.cost_s * 1e6:.1f} us)")
print(p.explain())                       # roofline terms + alternatives

p = planner.plan("svd", {"m": 100_000, "n": 4096, "k": 32},
                 context={"kind": "row"})
print(p.explain())                       # why gram beats lanczos here

# Calibration closes the loop: benchmark sweeps record measured timings,
# MachineModel.calibrate() regresses effective MXU/HBM efficiencies per
# backend+dtype from them (least squares on the roofline terms), and the
# fit persists next to the autotune config cache, where every later
# plan() prefers it:
#
#     PYTHONPATH=src python -m benchmarks.bench_planner
#
# emits BENCH json with modeled-vs-measured error before/after (the
# "tightened" line), writes machine.json, and re-plans a golden shape to
# show `calibrated: true`.  `python -m benchmarks.run --only planner`
# runs the same thing inside the benchmark harness.

# --- Multi-host execution: pricing the collectives ------------------------
# On one host the psum at the end of gram/fused_grad/rmatvec is free; on a
# pod it dominates.  Passing the mesh topology to plan() prices the
# collective end-to-end — ring vs tree reduction chosen by payload and
# axis sizes, and a chunk count scheduled when splitting the shard into
# column segments lets segment k's partial psum overlap segment k+1's
# compute:
from repro.launch import machine

p = planner.plan("gram", {"m": 1_000_000 // 64, "n": 1024},
                 machine=machine.V5E, context={"axes": (64,)})
print(f"\ngram on 64 devices -> {p.choice} "
      f"(chunks={p.blocks['chunks']})")
print(p.explain())        # the "comm:" line shows the modeled psum share

# The distmat methods consult the same plan: gram()/fused_grad() default
# to chunks="auto" (eager single-dispatch whenever the modeled psum is not
# worth hiding — always on one device) and accept an explicit chunk count.
# Chunked and eager results are BIT-identical; only the dispatch schedule
# changes.  telemetry spans around each collective feed plan-vs-actual
# records, so MachineModel.calibrate() can fit link efficiencies from
# production traces or from the sweep in:
#
#     PYTHONPATH=src python -m benchmarks.run --only collectives
#
# (modeled-vs-measured psum time by payload size and device count, plus a
# link_eff fit demo; CI uploads the BENCH json as a workflow artifact.)
_ = rm.gram(chunks=4)     # forced overlap: same bits as rm.gram(chunks=1)

# --- Serving: many users, one A-pass --------------------------------------
# launch/serve.py turns the solver into a frontend.  Requests that share a
# design matrix are grouped, and the WHOLE group advances with ONE fused
# multi-RHS A-pass per solver iteration — three users below cost the same
# matrix traffic per iteration as one.  The queue is continuously batched
# (requests join/leave between iterations, not between solves) and
# admission is planner-priced: plan() prices each request, the scheduler
# packs a device-time budget per step, joining an active group is free.
from repro import api
from repro.launch.serve import SolverServer

server = SolverServer(slots=8)
b1, b2, b3 = (jnp.asarray((A @ rng.normal(size=64)).astype(np.float32))
              for _ in range(3))
ids = [server.submit(api.SolveRequest(A=A, b=bi, loss="quad",
                                      method="gra", tol=1e-6))
       for bi in (b1, b2, b3)]
server.run()
infos = [server.result(i).info for i in ids]
print(f"\nserved {len(ids)} requests in one group "
      f"(plan: {infos[0]['plan']}); amortized A-passes per request: "
      f"{[int(i['a_passes']) for i in infos]} — one fused pass per "
      f"iteration covers the whole group")

# Benchmark it as a service (requests/sec, p50/p99 latency, batched-vs-
# serial throughput under a shared-matrix trace):
#
#     PYTHONPATH=src python -m benchmarks.run --only serve

# --- Fault tolerance & resumable solves ------------------------------------
# The elastic executor (core/optim/elastic.py) runs group solves one
# jitted iteration at a time on the host, which is what makes them
# interruptible: between iterations it can checkpoint, retry a transient
# failure (rollback is free — the step is only committed after it
# validates), or re-mesh the matrix off a straggling/lost shard detected
# by train/straggler.py's ShardMonitor.  Solver state lives on the
# driver, so a re-mesh moves only the matrix and the iteration counter
# never rewinds.  train/faults.py injects all three fault kinds
# deterministically for tests and benchmarks.

# Resumable solves: checkpoint_dir snapshots optimizer state every
# `checkpoint_every` iterations (async, fsync'd, torn-write-safe);
# resume=True restores the latest snapshot bit-compatibly and continues.
import tempfile

ckdir = tempfile.mkdtemp()
r1 = api.solve(api.SolveRequest(A=A, b=jnp.asarray(b), loss="quad",
                                tol=0.0, max_iters=10,
                                checkpoint_dir=ckdir, checkpoint_every=5))
r2 = api.solve(api.SolveRequest(A=A, b=jnp.asarray(b), loss="quad",
                                tol=0.0, max_iters=20,
                                checkpoint_dir=ckdir, resume=True))
print(f"\nresumable solve: run 1 stopped at {r1.info['iterations']} "
      f"({r1.info['checkpoint_saves']} checkpoints); run 2 resumed from "
      f"{r2.info['resumed_from']} and reached {r2.info['iterations']} — "
      f"bit-identical to an uninterrupted run")

# Serving degrades gracefully instead of failing: per-request deadline_s
# and max_iters return the best iterate with converged=False and a typed
# info["degraded"] reason ("deadline" / "max_iterations" / "fault");
# a full queue sheds load with an api.Overloaded result instead of
# growing without bound.
r3 = api.solve(api.SolveRequest(A=A, b=jnp.asarray(b), loss="quad",
                                tol=0.0, max_iters=5, deadline_s=30.0))
print(f"degraded solve: converged={r3.info['converged']} "
      f"(reason: {r3.info['degraded']}) — best iterate still returned")

# The fault-injection suite (tests/test_fault_tolerance.py, marker
# `fault`) exercises straggler→re-mesh→parity, kill→resume→bit-equality
# and deadline retirement on 1- and 8-device meshes; the recovery
# overhead (throughput under 0/1/2 injected stragglers, straggler-onset→
# re-mesh latency) is benchmarked by the serve_recovery BENCH line of
#
#     PYTHONPATH=src python -m benchmarks.run --only serve

# --- Observability: spans, metrics, plan-vs-actual -------------------------
# Every solve can be traced (launch/telemetry.py): telemetry=True runs the
# request under a fresh Recorder and attaches info["trace"] — per-phase
# span timings (iteration / fused A-pass / checkpoint / re-mesh), server
# queue-wait and latency histograms, and one plan-vs-actual record per
# engine step tying the planner's modeled cost to the measured wall time.
# Off by default: the disabled path is shared no-op singletons.
from repro.launch import telemetry

rec = telemetry.Recorder()
rt = api.solve(api.SolveRequest(A=A, b=jnp.asarray(b), loss="quad",
                                tol=0.0, max_iters=10,
                                checkpoint_dir=ckdir, telemetry=rec))
trace = rt.info["trace"]
print(f"\ntraced solve: {trace['spans']} spans; per-phase totals:",
      {k: round(v["total_s"], 4) for k, v in trace["phases"].items()})

# The same recorder scopes a whole serving session: build the server under
# telemetry.recording() and its scheduler actions (admit / retire / shed)
# are spanned, queue-wait/latency histograms filled, and degraded
# retirements counted per reason (server.stats["degraded"]).
with telemetry.recording(rec):
    traced_srv = SolverServer(slots=2)
    tid = traced_srv.submit(api.SolveRequest(A=A, b=jnp.asarray(b1),
                                             loss="quad", tol=1e-6))
    traced_srv.run()
lat = traced_srv.tel.histogram("serve.latency_s")
print(f"served p50 latency: {lat.percentile(0.5) * 1e3:.1f} ms "
      f"(stats: {traced_srv.stats})")

# Exports: rec.export_jsonl(path) writes one JSON event per line;
# rec.export_chrome_trace(path) writes a Chrome/Perfetto trace (open at
# https://ui.perfetto.dev).  rec.calibration_records() feeds
# planner.calibrate() so the cost model learns from production traces —
# the same loop benchmarks/bench_serve.py --traced-demo packages for CI.
