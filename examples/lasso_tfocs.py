"""Paper §3.2.2 — LASSO with the three-part composite objective, showing
the explicit (linear, smooth, nonsmooth) decomposition and the solver
variants from Figure 1.

    PYTHONPATH=src python examples/lasso_tfocs.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.distmat import RowMatrix
from repro.core.tfocs import (LinopMatrix, SmoothQuad, ProxL1, tfocs,
                              TfocsOptions)

rng = np.random.default_rng(1)
m, n = 2000, 256
A = rng.normal(size=(m, n)).astype(np.float32)
xt = np.zeros(n, np.float32)
xt[:10] = rng.normal(size=10) * 2
b = (A @ xt + 0.05 * rng.normal(size=m)).astype(np.float32)
lam = 1.0

rm = RowMatrix.create(A)
linop = LinopMatrix(rm)                       # the expensive, distributed part
smooth = SmoothQuad(b=linop.pad_data(jnp.asarray(b)),
                    weights=linop.row_weights())
prox = ProxL1(lam)                            # driver-local vector math

for name, opts in {
    "gra":    TfocsOptions(max_iters=300, accel=False, backtracking=False,
                           Lexact=float(np.linalg.norm(A, 2) ** 2)),
    "acc":    TfocsOptions(max_iters=300, backtracking=False,
                           Lexact=float(np.linalg.norm(A, 2) ** 2)),
    "acc_rb": TfocsOptions(max_iters=300, backtracking=True, restart=True),
}.items():
    x, info = tfocs(smooth, linop, prox, jnp.zeros(n), opts)
    f = 0.5 * np.linalg.norm(A @ np.asarray(x) - b) ** 2 \
        + lam * np.abs(np.asarray(x)).sum()
    print(f"{name:7s} f={f:10.4f} iters={int(info['iterations']):4d} "
          f"backtracks={int(info['n_backtracks']):3d} "
          f"restarts={int(info['n_restarts'])}")
