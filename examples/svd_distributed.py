"""Paper §3.1 / Table 1 — distributed SVD at Netflix-prize-like aspect
ratios (scaled to this machine), via all three code paths (Gram,
matrix-free Lanczos, and the randomized range finder).

    PYTHONPATH=src python examples/svd_distributed.py

On TPU the per-shard hotspots (Gram reduction, randomized-SVD projection,
U recovery) run through the Pallas kernels with `tune="auto"` block sizes:
the shape-aware autotuner (repro.kernels.autotune) picks tiles per
(backend, dtype, shape-bucket) from its persistent JSON cache
($REPRO_AUTOTUNE_CACHE or ~/.cache/repro/autotune.json, with shipped v5e
defaults), falling back to roofline cost-model ranking.  Re-sweep on new
hardware with `python -m benchmarks.bench_autotune`.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.distmat import CoordinateMatrix, RowMatrix
from repro.core.linalg import compute_svd

rng = np.random.default_rng(0)

# Netflix-shaped (17770 × 480189 in the paper; transpose-scaled here):
# tall-skinny path — Gram on the "driver", U recovered in parallel.
A = rng.normal(size=(50_000, 128)).astype(np.float32)
t0 = time.time()
res = compute_svd(RowMatrix.create(A), k=5)
print(f"tall-skinny ({A.shape}): mode={res.info['mode']} "
      f"σ={np.round(np.asarray(res.s), 2)}  [{time.time()-t0:.2f}s]")

# square sparse path — ARPACK-analogue Lanczos, matrix-free matvecs.
m = n = 5000
nnz = 100_000
ri, ci = rng.integers(0, m, nnz), rng.integers(0, n, nnz)
va = rng.normal(size=nnz).astype(np.float32)
cm = CoordinateMatrix.create(jnp.asarray(ri), jnp.asarray(ci),
                             jnp.asarray(va), (m, n))
t0 = time.time()
res = compute_svd(cm, k=5, mode="lanczos", tol=1e-4)
print(f"square sparse ({m}x{n}, nnz={nnz}): "
      f"σ={np.round(np.asarray(res.s), 3)} "
      f"restarts={int(res.info['restarts'])}  [{time.time()-t0:.2f}s]")

# moderately-rectangular dense path — randomized range finder: too wide for
# a comfortable driver-side Gram, dense enough that Lanczos pays one full
# pass over A per extracted direction; the sketch needs 2+2q passes total.
W = rng.normal(size=(30_000, 2048)).astype(np.float32)
W[:, :16] *= np.linspace(40.0, 8.0, 16)[None, :]     # plant a signal
t0 = time.time()
res = compute_svd(RowMatrix.create(W), k=8, mode="randomized")
print(f"wide dense ({W.shape}): mode={res.info['mode']} "
      f"passes={res.info['passes_over_A']} "
      f"tail_ratio={float(res.info['tail_ratio']):.3f} "
      f"σ={np.round(np.asarray(res.s), 2)}  [{time.time()-t0:.2f}s]")
