"""LLM serving demo: batched prefill + token-by-token decode.

    PYTHONPATH=src python examples/serve_llm.py --arch qwen3-4b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the full generation path (prefill → KV/SSM cache → decode loop
→ greedy sampling) on real devices; the same prefill/decode functions are
what the dry-run lowers at production shapes.  (This used to live at
repro/launch/serve.py; that module is now the *solver* serving frontend —
the request-batched linear-algebra server.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import build, smoke_config
from repro.models.sharding import use_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    mesh = make_host_mesh(args.data, args.model)
    rng = np.random.default_rng(0)

    with mesh, use_mesh(mesh):
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = args.batch, args.prompt_len
        total = S + args.gen
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
        if cfg.frontend:
            flen = S if cfg.family == "encdec" else cfg.frontend_len
            batch["frontend_embeds"] = jnp.asarray(
                rng.normal(size=(B, flen, cfg.d_model)) * 0.02, jnp.float32)
        if cfg.family == "encdec":
            caches, _ = model.init_caches(B, total, S)
        else:
            caches, _ = model.init_caches(B, total)

        prefill = jax.jit(model.prefill)
        decode = jax.jit(model.decode_step, donate_argnums=(2,))

        t0 = time.time()
        logits, caches = prefill(params, batch, caches)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        out_tokens = [jnp.argmax(logits[:, -1], -1)[:, None]]
        pos = jnp.int32(S)
        t0 = time.time()
        for _ in range(args.gen - 1):
            logits, caches = decode(params, out_tokens[-1], caches, pos)
            out_tokens.append(jnp.argmax(logits[:, -1], -1)[:, None])
            pos = pos + 1
        jax.block_until_ready(out_tokens[-1])
        t_decode = time.time() - t0

        gen = np.asarray(jnp.concatenate(out_tokens, 1))
        print(f"prefill: {t_prefill*1e3:.1f}ms for {B}x{S} tokens")
        print(f"decode : {t_decode/max(args.gen-1,1)*1e3:.1f}ms/token "
              f"(batch {B})")
        print("generated token ids (first row):", gen[0][:16])


if __name__ == "__main__":
    main()
